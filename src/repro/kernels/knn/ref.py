"""Pure-jnp oracle for the KNN (nearest-approximizer) lookup.

Semantics shared with the Pallas kernel (knn.py) and the jit wrapper
(ops.py): given queries (Q, D) and keys (K, D), return per query the
minimum dissimilarity cost d(q, k)^γ and the argmin key index.
Ties break toward the lowest index (both implementations scan keys in
ascending order and use strict < for updates).
"""
from __future__ import annotations

import jax.numpy as jnp

_INF = 3.0e38


def _dense_ca(queries: jnp.ndarray, keys: jnp.ndarray, metric: str,
              gamma: float) -> jnp.ndarray:
    """Dense (Q, K) approximation-cost matrix C_a = d(q, k)^γ, f32 —
    the one definition of the oracles' distance block (kernels keep
    their own tiled `_distance_block`)."""
    q = queries.astype(jnp.float32)
    k = keys.astype(jnp.float32)
    if metric == "l1":
        d = jnp.sum(jnp.abs(q[:, None, :] - k[None, :, :]), axis=-1)
    elif metric in ("l2", "l2sq"):
        d2 = (jnp.sum(q * q, -1)[:, None] + jnp.sum(k * k, -1)[None, :]
              - 2.0 * q @ k.T)
        d2 = jnp.maximum(d2, 0.0)
        d = d2 if metric == "l2sq" else jnp.sqrt(d2)
    else:
        raise ValueError(metric)
    return d if gamma == 1.0 else jnp.power(jnp.maximum(d, 0.0), gamma)


def knn_ref(queries: jnp.ndarray, keys: jnp.ndarray, metric: str = "l2",
            gamma: float = 1.0) -> tuple[jnp.ndarray, jnp.ndarray]:
    cost = _dense_ca(queries, keys, metric, gamma)
    idx = jnp.argmin(cost, axis=1).astype(jnp.int32)
    return jnp.min(cost, axis=1), idx


def placement_gains_ref(x: jnp.ndarray, y: jnp.ndarray, lam: jnp.ndarray,
                        cur: jnp.ndarray, hreq: jnp.ndarray,
                        metric: str = "l2", gamma: float = 1.0
                        ) -> jnp.ndarray:
    """Oracle for the placement gain kernel (kernels.knn.gains).

    x: (R, D) request-object coords; y: (O, D) candidates; lam, cur:
    (I, R) per-(ingress, object) rates / current serving costs; hreq:
    (I, J) retrieval costs (+inf ⇒ off-path ⇒ zero gain). Returns the
    (O, J) marginal gains

        gain[o', j] = Σ_i Σ_r λ[i, r]·relu(cur[i, r] − C_a(x_r, y_{o'})
                                            − H[i, j])

    materializing the full (I, R, O, J) slack tensor — small instances
    only; the kernel and its blocked jnp twin stream tiles instead.
    """
    ca = _dense_ca(x, y, metric, gamma)
    slack = (cur[:, :, None, None] - ca[None, :, :, None]
             - hreq[:, None, None, :])                       # (I, R, O, J)
    slack = jnp.where(jnp.isnan(slack), -jnp.inf, slack)     # inf − inf
    return jnp.sum(lam[:, :, None, None].astype(jnp.float32)
                   * jnp.maximum(slack, 0.0), axis=(0, 1))


def fused_lookup_ref(queries: jnp.ndarray, keys: jnp.ndarray,
                     h_key: jnp.ndarray, meta: jnp.ndarray,
                     metric: str = "l2", gamma: float = 1.0,
                     h_repo: float = 0.0, repo_level: int = -1,
                     fold_repo: bool = True) -> tuple[jnp.ndarray, ...]:
    """Oracle for the fused multi-level lookup (see ops.fused_lookup).

    Same semantics as the Pallas kernel: invalid keys (meta row 3 == 0)
    are masked to +INF before the min; the repository wins only on strict
    improvement (a cache tying h_repo serves the request); ties among
    keys break to the lowest concatenated index, i.e. lowest level then
    lowest slot. ``fold_repo=False`` mirrors the kernel's shard-local
    entry: no repository fold, and a segment with no valid key returns
    (+INF, 0, repo_level, 0, −1) — the kernel's untouched init state.
    """
    ca = _dense_ca(queries, keys, metric, gamma)
    valid = (meta[3, :] > 0)[None, :]
    cost = jnp.where(valid, ca + h_key[None, :].astype(jnp.float32), _INF)
    best = jnp.argmin(cost, axis=1)
    bcost = jnp.min(cost, axis=1)
    bca = jnp.where(valid[0, best],
                    ca[jnp.arange(queries.shape[0]), best], 0.0)
    # strict <: when nothing is valid (bcost == _INF) the "winner" is the
    # masked key 0 — overridden by either the repo fold or the shard-local
    # init-state defaults below.
    use_repo = (h_repo < bcost) if fold_repo else (bcost >= _INF)
    rcost = jnp.float32(h_repo) if fold_repo else bcost
    i32 = lambda x: x.astype(jnp.int32)                      # noqa: E731
    return (jnp.where(use_repo, rcost, bcost),
            jnp.where(use_repo, 0.0, bca),
            i32(jnp.where(use_repo, repo_level, meta[0, best])),
            i32(jnp.where(use_repo, 0, meta[1, best])),
            i32(jnp.where(use_repo, -1, meta[2, best])))


def pad_to_shards(keys: jnp.ndarray, h_key: jnp.ndarray,
                  meta: jnp.ndarray, n_shards: int
                  ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Pad the segmented key tensor so the key axis divides ``n_shards``.

    Padding keys are all-zero with h == 0, valid == 0 and payload == −1
    — masked explicitly by the kernel, so contiguous balanced chunks
    never perturb a distance. The single definition of the shard-padding
    contract: SimCacheNetwork.sharded_layout (production) and
    sharded_fused_lookup_ref (oracle) both use it.
    """
    pad = (-keys.shape[0]) % n_shards
    if pad:
        keys = jnp.concatenate(
            [keys, jnp.zeros((pad, keys.shape[1]), keys.dtype)])
        h_key = jnp.concatenate([h_key, jnp.zeros((pad,), h_key.dtype)])
        mpad = jnp.zeros((4, pad), meta.dtype).at[2, :].set(-1)
        meta = jnp.concatenate([meta, mpad], axis=1)
    return keys, h_key, meta


def reduce_shard_minima(cost_s: jnp.ndarray, ca_s: jnp.ndarray,
                        lvl_s: jnp.ndarray, slot_s: jnp.ndarray,
                        pay_s: jnp.ndarray, h_repo: float,
                        repo_level: int = -1) -> tuple[jnp.ndarray, ...]:
    """Reduce per-shard (n_shards, B) lookup minima to the global winner.

    Lexicographic argmin: minimum cost, ties to the *lowest shard index*
    (``jnp.argmin`` keeps the first minimum). Shards are contiguous
    balanced chunks of the level-ordered concatenated key tensor, so
    (shard index, within-shard index) order equals concatenated-index
    order and the tie-break matches the single-device fused kernel's
    running strict-< min exactly. The repository is folded once here, on
    strict improvement — never inside a shard. Shared by the shard_map
    path (ops.sharded_fused_lookup) and the mesh-free oracle below.
    """
    best = jnp.argmin(cost_s, axis=0)
    take = lambda x: jnp.take_along_axis(              # noqa: E731
        x, best[None, :], axis=0)[0]
    bcost, bca = take(cost_s), take(ca_s)
    blvl, bslot, bpay = take(lvl_s), take(slot_s), take(pay_s)
    use_repo = h_repo < bcost
    i32 = lambda x: x.astype(jnp.int32)                # noqa: E731
    return (jnp.where(use_repo, jnp.float32(h_repo), bcost),
            jnp.where(use_repo, 0.0, bca),
            i32(jnp.where(use_repo, repo_level, blvl)),
            i32(jnp.where(use_repo, 0, bslot)),
            i32(jnp.where(use_repo, -1, bpay)))


def pruned_fused_lookup_ref(queries: jnp.ndarray, keys: jnp.ndarray,
                            h_key: jnp.ndarray, meta: jnp.ndarray,
                            tables, cap_union: int, metric: str = "l2",
                            gamma: float = 1.0, h_repo: float = 0.0,
                            repo_level: int = -1, fold_repo: bool = True
                            ) -> tuple[jnp.ndarray, ...]:
    """Oracle for the pruned gather-variant lookup (ops.
    pruned_fused_lookup): identical candidate hashing, union, and row
    gather (shared helpers in kernels.knn.lsh), but the scan runs
    through :func:`fused_lookup_ref` instead of the Pallas kernel.
    ``tables`` is a lsh.CandidateTables. Returns the same
    (cost, approx_cost, level, slot, payload, bound) tuple.
    """
    from repro.kernels.knn.lsh import (candidate_matrix, candidate_union,
                                       gather_candidate_rows,
                                       unscanned_h_bound)
    if keys.shape[0] == 0:
        out = fused_lookup_ref(queries, keys, h_key, meta, metric=metric,
                               gamma=gamma, h_repo=h_repo,
                               repo_level=repo_level, fold_repo=fold_repo)
        return (*out, jnp.float32(_INF))
    cand = candidate_matrix(tables.kind, jnp.asarray(tables.proj),
                            jnp.asarray(tables.buckets), queries,
                            tables.n_probes)
    kept, kept_mask = candidate_union(cand, keys.shape[0], cap_union)
    gk, gh, gm = gather_candidate_rows(keys, h_key, meta, kept)
    out = fused_lookup_ref(queries, gk, gh, gm, metric=metric, gamma=gamma,
                           h_repo=h_repo, repo_level=repo_level,
                           fold_repo=fold_repo)
    return (*out, unscanned_h_bound(h_key, meta, kept_mask))


def sharded_pruned_fused_lookup_ref(queries: jnp.ndarray,
                                    keys: jnp.ndarray, h_key: jnp.ndarray,
                                    meta: jnp.ndarray, tables: list,
                                    cap_union: int, metric: str = "l2",
                                    gamma: float = 1.0, h_repo: float = 0.0,
                                    repo_level: int = -1
                                    ) -> tuple[jnp.ndarray, ...]:
    """Mesh-free oracle of ops.sharded_pruned_fused_lookup: chunk the
    (already shard-padded) key tensor into ``len(tables)`` contiguous
    balanced chunks, prune each with its *own* per-shard tables
    (``fold_repo=False``), reduce with the untouched
    :func:`reduce_shard_minima`, and return the min of the per-shard
    un-scanned-h bounds. Runs on one device at any shard count, like
    :func:`sharded_fused_lookup_ref`.
    """
    n_shards = len(tables)
    keys, h_key, meta = pad_to_shards(keys, h_key, meta, n_shards)
    S = keys.shape[0] // n_shards
    parts = [pruned_fused_lookup_ref(
        queries, keys[s * S:(s + 1) * S], h_key[s * S:(s + 1) * S],
        meta[:, s * S:(s + 1) * S], tables[s], cap_union, metric=metric,
        gamma=gamma, h_repo=h_repo, repo_level=repo_level,
        fold_repo=False) for s in range(n_shards)]
    stk = [jnp.stack([p[i] for p in parts]) for i in range(5)]
    red = reduce_shard_minima(*stk, h_repo=h_repo, repo_level=repo_level)
    return (*red, jnp.min(jnp.stack([p[5] for p in parts])))


def quantized_fused_lookup_ref(queries: jnp.ndarray, keys: jnp.ndarray,
                               h_key: jnp.ndarray, meta: jnp.ndarray,
                               kq=None, top_t: int = 64,
                               metric: str = "l2", gamma: float = 1.0,
                               h_repo: float = 0.0, repo_level: int = -1,
                               fold_repo: bool = True
                               ) -> tuple[jnp.ndarray, ...]:
    """Oracle for the compressed-first-pass lookup (ops.
    quantized_fused_lookup): identical first-pass selection and union
    gather (shared helpers), but the exact rescore runs through
    :func:`fused_lookup_ref`. ``kq`` (quant.quantize_rows of ``keys``)
    is built on the fly when omitted. Returns (cost, approx_cost,
    level, slot, payload, bound) with the per-query (B,) vT bound.
    """
    from repro.kernels import quant
    from repro.kernels.knn.lsh import (candidate_union,
                                       gather_candidate_rows)
    from repro.kernels.knn.ops import _quant_union_cap, _quantized_select
    nq = queries.shape[0]
    if keys.shape[0] == 0:
        out = fused_lookup_ref(queries, keys, h_key, meta, metric=metric,
                               gamma=gamma, h_repo=h_repo,
                               repo_level=repo_level, fold_repo=fold_repo)
        return (*out, jnp.full((nq,), _INF, jnp.float32))
    if kq is None:
        kq = quant.quantize_rows(jnp.asarray(keys, jnp.float32), metric)
    cand, bound = _quantized_select(
        jnp.asarray(queries, jnp.float32), jnp.asarray(h_key),
        jnp.asarray(meta)[3, :] > 0, kq, top_t, keys.shape[0], metric,
        gamma)
    kept, _ = candidate_union(cand, keys.shape[0],
                              _quant_union_cap(keys.shape[0], nq, top_t))
    gk, gh, gm = gather_candidate_rows(jnp.asarray(keys),
                                       jnp.asarray(h_key),
                                       jnp.asarray(meta), kept)
    out = fused_lookup_ref(queries, gk, gh, gm, metric=metric, gamma=gamma,
                           h_repo=h_repo, repo_level=repo_level,
                           fold_repo=fold_repo)
    return (*out, bound)


def sharded_quantized_fused_lookup_ref(queries: jnp.ndarray,
                                       keys: jnp.ndarray,
                                       h_key: jnp.ndarray,
                                       meta: jnp.ndarray, n_shards: int,
                                       top_t: int = 64, metric: str = "l2",
                                       gamma: float = 1.0,
                                       h_repo: float = 0.0,
                                       repo_level: int = -1
                                       ) -> tuple[jnp.ndarray, ...]:
    """Mesh-free oracle of ops.sharded_quantized_fused_lookup: chunk the
    shard-padded key tensor, run the compressed lookup per chunk
    (``fold_repo=False``; per-row quantization makes the chunked int8
    image identical to chunking a whole-tensor quantization), reduce
    with :func:`reduce_shard_minima`, and take the per-query min of the
    per-shard vT bounds.
    """
    keys, h_key, meta = pad_to_shards(keys, h_key, meta, n_shards)
    S = keys.shape[0] // n_shards
    parts = [quantized_fused_lookup_ref(
        queries, keys[s * S:(s + 1) * S], h_key[s * S:(s + 1) * S],
        meta[:, s * S:(s + 1) * S], top_t=top_t, metric=metric,
        gamma=gamma, h_repo=h_repo, repo_level=repo_level,
        fold_repo=False) for s in range(n_shards)]
    stk = [jnp.stack([p[i] for p in parts]) for i in range(5)]
    red = reduce_shard_minima(*stk, h_repo=h_repo, repo_level=repo_level)
    return (*red, jnp.min(jnp.stack([p[5] for p in parts]), axis=0))


def sharded_fused_lookup_ref(queries: jnp.ndarray, keys: jnp.ndarray,
                             h_key: jnp.ndarray, meta: jnp.ndarray,
                             n_shards: int, metric: str = "l2",
                             gamma: float = 1.0, h_repo: float = 0.0,
                             repo_level: int = -1
                             ) -> tuple[jnp.ndarray, ...]:
    """Mesh-free oracle of the sharded fused lookup (ops.
    sharded_fused_lookup): pad the concatenated key tensor to a multiple
    of ``n_shards``, split it into contiguous balanced chunks, take each
    chunk's local minimum with ``fold_repo=False``, and reduce with
    :func:`reduce_shard_minima`.

    Runs on a single device (plain chunking stands in for shard_map), so
    the differential suite can exercise every shard count without an
    8-device mesh.
    """
    keys, h_key, meta = pad_to_shards(keys, h_key, meta, n_shards)
    S = keys.shape[0] // n_shards
    parts = [fused_lookup_ref(
        queries, keys[s * S:(s + 1) * S], h_key[s * S:(s + 1) * S],
        meta[:, s * S:(s + 1) * S], metric=metric, gamma=gamma,
        h_repo=h_repo, repo_level=repo_level, fold_repo=False)
        for s in range(n_shards)]
    stk = [jnp.stack([p[i] for p in parts]) for i in range(5)]  # (n, B) × 5
    return reduce_shard_minima(*stk, h_repo=h_repo,
                               repo_level=repo_level)
