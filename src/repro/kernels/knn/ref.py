"""Pure-jnp oracle for the KNN (nearest-approximizer) lookup.

Semantics shared with the Pallas kernel (knn.py) and the jit wrapper
(ops.py): given queries (Q, D) and keys (K, D), return per query the
minimum dissimilarity cost d(q, k)^γ and the argmin key index.
Ties break toward the lowest index (both implementations scan keys in
ascending order and use strict < for updates).
"""
from __future__ import annotations

import jax.numpy as jnp

_INF = 3.0e38


def knn_ref(queries: jnp.ndarray, keys: jnp.ndarray, metric: str = "l2",
            gamma: float = 1.0) -> tuple[jnp.ndarray, jnp.ndarray]:
    q = queries.astype(jnp.float32)
    k = keys.astype(jnp.float32)
    if metric == "l1":
        d = jnp.sum(jnp.abs(q[:, None, :] - k[None, :, :]), axis=-1)
    elif metric in ("l2", "l2sq"):
        d2 = (jnp.sum(q * q, -1)[:, None] + jnp.sum(k * k, -1)[None, :]
              - 2.0 * q @ k.T)
        d2 = jnp.maximum(d2, 0.0)
        d = d2 if metric == "l2sq" else jnp.sqrt(d2)
    else:
        raise ValueError(metric)
    cost = d if gamma == 1.0 else jnp.power(jnp.maximum(d, 0.0), gamma)
    idx = jnp.argmin(cost, axis=1).astype(jnp.int32)
    return jnp.min(cost, axis=1), idx


def fused_lookup_ref(queries: jnp.ndarray, keys: jnp.ndarray,
                     h_key: jnp.ndarray, meta: jnp.ndarray,
                     metric: str = "l2", gamma: float = 1.0,
                     h_repo: float = 0.0, repo_level: int = -1
                     ) -> tuple[jnp.ndarray, ...]:
    """Oracle for the fused multi-level lookup (see ops.fused_lookup).

    Same semantics as the Pallas kernel: invalid keys (meta row 3 == 0)
    are masked to +INF before the min; the repository wins only on strict
    improvement (a cache tying h_repo serves the request); ties among
    keys break to the lowest concatenated index, i.e. lowest level then
    lowest slot.
    """
    q = queries.astype(jnp.float32)
    k = keys.astype(jnp.float32)
    if metric == "l1":
        d = jnp.sum(jnp.abs(q[:, None, :] - k[None, :, :]), axis=-1)
    elif metric in ("l2", "l2sq"):
        d2 = (jnp.sum(q * q, -1)[:, None] + jnp.sum(k * k, -1)[None, :]
              - 2.0 * q @ k.T)
        d2 = jnp.maximum(d2, 0.0)
        d = d2 if metric == "l2sq" else jnp.sqrt(d2)
    else:
        raise ValueError(metric)
    ca = d if gamma == 1.0 else jnp.power(jnp.maximum(d, 0.0), gamma)
    valid = (meta[3, :] > 0)[None, :]
    cost = jnp.where(valid, ca + h_key[None, :].astype(jnp.float32), _INF)
    best = jnp.argmin(cost, axis=1)
    bcost = jnp.min(cost, axis=1)
    bca = jnp.where(valid[0, best], ca[jnp.arange(q.shape[0]), best], 0.0)
    use_repo = h_repo < bcost
    i32 = lambda x: x.astype(jnp.int32)                      # noqa: E731
    return (jnp.where(use_repo, h_repo, bcost),
            jnp.where(use_repo, 0.0, bca),
            i32(jnp.where(use_repo, repo_level, meta[0, best])),
            i32(jnp.where(use_repo, 0, meta[1, best])),
            i32(jnp.where(use_repo, -1, meta[2, best])))
