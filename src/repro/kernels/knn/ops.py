"""Jitted public wrapper around the KNN Pallas kernel.

Handles padding (queries → BQ multiple with zeros, keys → BK multiple by
repeating key 0 so ties break to the genuine lower index, feature dim →
lane multiple with zeros, which preserves both L1 and L2 distances), and
falls back to the pure-jnp oracle on platforms without Pallas TPU support
unless ``interpret=True`` (the default off-TPU) is requested.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.knn.knn import DEFAULT_BK, DEFAULT_BQ, knn_pallas
from repro.kernels.knn.ref import knn_ref

LANE = 128


def _pad_axis(x: jax.Array, mult: int, axis: int, mode: str) -> jax.Array:
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    if mode == "zero":
        widths = [(0, 0)] * x.ndim
        widths[axis] = (0, pad)
        return jnp.pad(x, widths)
    if mode == "repeat_first":
        first = jax.lax.slice_in_dim(x, 0, 1, axis=axis)
        reps = jnp.concatenate([first] * pad, axis=axis)
        return jnp.concatenate([x, reps], axis=axis)
    raise ValueError(mode)


def pad_for_knn(queries: jax.Array, keys: jax.Array, bq: int, bk: int
                ) -> tuple[jax.Array, jax.Array]:
    queries = _pad_axis(_pad_axis(queries, LANE, 1, "zero"), bq, 0, "zero")
    keys = _pad_axis(_pad_axis(keys, LANE, 1, "zero"), bk, 0, "repeat_first")
    return queries, keys


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("metric", "gamma", "bq", "bk",
                                              "use_pallas", "interpret"))
def nearest_approximizer(queries: jax.Array, keys: jax.Array,
                         metric: str = "l2", gamma: float = 1.0,
                         bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                         use_pallas: bool = True,
                         interpret: bool | None = None
                         ) -> tuple[jax.Array, jax.Array]:
    """min_k C_a(q, key_k) and the argmin index, per query.

    The public lookup primitive of the similarity cache: returns the
    dissimilarity cost d(q, k)^γ of the best stored approximizer and its
    slot index.
    """
    nq = queries.shape[0]
    if not use_pallas:
        return knn_ref(queries, keys, metric, gamma)
    if interpret is None:
        interpret = not _on_tpu()
    qp, kp = pad_for_knn(queries.astype(jnp.float32),
                         keys.astype(jnp.float32), bq, bk)
    mind, argm = knn_pallas(qp, kp, metric=metric, gamma=gamma, bq=bq, bk=bk,
                            interpret=interpret)
    return mind[:nq], argm[:nq]
