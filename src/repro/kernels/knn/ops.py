"""Jitted public wrapper around the KNN Pallas kernel.

Handles padding (queries → BQ multiple with zeros, keys → BK multiple by
repeating key 0 so ties break to the genuine lower index, feature dim →
lane multiple with zeros, which preserves both L1 and L2 distances), and
falls back to the pure-jnp oracle on platforms without Pallas TPU support
unless ``interpret=True`` (the default off-TPU) is requested.

``sharded_fused_lookup`` is the SPMD data-plane entry: the segmented key
tensor lives sharded across a mesh axis, each shard runs the fused
segmented-1-NN kernel locally with ``fold_repo=False``, and the per-shard
(cost, C_a, level, slot, payload) minima — 5 scalars per query per shard,
a tiny fraction of the key tensor — are gathered and reduced
lexicographically by ``reduce_shard_minima``, which also folds the
repository exactly once. Contiguous balanced shards + first-min
tie-breaking make the result bit-identical to the single-device fused
path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro import tracecount
from repro.kernels import quant
from repro.kernels.knn.knn import (DEFAULT_BK, DEFAULT_BQ, _INF,
                                   fused_lookup_pallas, knn_pallas)
from repro.kernels.knn.lsh import (candidate_matrix, candidate_union,
                                   gather_candidate_rows, unscanned_h_bound)
from repro.kernels.knn.ref import (fused_lookup_ref, knn_ref,
                                   reduce_shard_minima)
from repro.kernels.quant import QuantizedRows

LANE = 128
DEFAULT_TOP_T = 64        # quantized first pass: exact-rescore width
DEFAULT_QTILE = 8192      # quantized first pass: key-axis tile


def _pad_axis(x: jax.Array, mult: int, axis: int, mode: str) -> jax.Array:
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    if mode == "zero":
        widths = [(0, 0)] * x.ndim
        widths[axis] = (0, pad)
        return jnp.pad(x, widths)
    if mode == "repeat_first":
        first = jax.lax.slice_in_dim(x, 0, 1, axis=axis)
        reps = jnp.concatenate([first] * pad, axis=axis)
        return jnp.concatenate([x, reps], axis=axis)
    raise ValueError(mode)


def pad_for_knn(queries: jax.Array, keys: jax.Array, bq: int, bk: int
                ) -> tuple[jax.Array, jax.Array]:
    queries = _pad_axis(_pad_axis(queries, LANE, 1, "zero"), bq, 0, "zero")
    keys = _pad_axis(_pad_axis(keys, LANE, 1, "zero"), bk, 0, "repeat_first")
    return queries, keys


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("metric", "gamma", "bq", "bk",
                                              "use_pallas", "interpret"))
def nearest_approximizer(queries: jax.Array, keys: jax.Array,
                         metric: str = "l2", gamma: float = 1.0,
                         bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                         use_pallas: bool = True,
                         interpret: bool | None = None
                         ) -> tuple[jax.Array, jax.Array]:
    """min_k C_a(q, key_k) and the argmin index, per query.

    The public lookup primitive of the similarity cache: returns the
    dissimilarity cost d(q, k)^γ of the best stored approximizer and its
    slot index.
    """
    nq = queries.shape[0]
    if not use_pallas:
        return knn_ref(queries, keys, metric, gamma)
    if interpret is None:
        interpret = not _on_tpu()
    qp, kp = pad_for_knn(queries.astype(jnp.float32),
                         keys.astype(jnp.float32), bq, bk)
    mind, argm = knn_pallas(qp, kp, metric=metric, gamma=gamma, bq=bq, bk=bk,
                            interpret=interpret)
    return mind[:nq], argm[:nq]


@functools.partial(jax.jit, static_argnames=(
    "metric", "gamma", "h_repo", "repo_level", "bq", "bk", "use_pallas",
    "interpret", "fold_repo"))
def fused_lookup(queries: jax.Array, keys: jax.Array, h_key: jax.Array,
                 meta: jax.Array, metric: str = "l2", gamma: float = 1.0,
                 h_repo: float = 0.0, repo_level: int = -1,
                 bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                 use_pallas: bool = True, interpret: bool | None = None,
                 fold_repo: bool = True) -> tuple[jax.Array, ...]:
    """Network-wide nearest-approximizer query, fused.

    ``keys`` (K, d) is the concatenation of every cache level's stored
    embeddings; ``h_key`` (K,) the per-key retrieval cost h(level(k));
    ``meta`` (4, K) i32 rows (level, slot, payload, valid). A single
    blocked scan returns, per query, argmin over all keys *and* the
    repository (a virtual key with C_a = 0, h = h_repo) of
    C_a(q, k)^γ + h — eq. (1) as one kernel launch. Returns
    (cost, approx_cost, level, slot, payload), each (B,).

    ``fold_repo=False`` returns the segment-local minimum only (the
    shard-local half of ``sharded_fused_lookup``); with no valid key the
    result is (+INF, 0, repo_level, 0, −1).
    """
    tracecount.bump("fused_lookup")          # once per trace, not per call
    nq = queries.shape[0]
    if keys.shape[0] == 0:          # no cache keys at all → repository
        cost0 = h_repo if fold_repo else _INF
        return (jnp.full((nq,), cost0, jnp.float32),
                jnp.zeros((nq,), jnp.float32),
                jnp.full((nq,), repo_level, jnp.int32),
                jnp.zeros((nq,), jnp.int32),
                jnp.full((nq,), -1, jnp.int32))
    h_row = h_key.reshape(1, -1).astype(jnp.float32)
    if not use_pallas:
        return fused_lookup_ref(queries, keys, h_row[0], meta, metric=metric,
                                gamma=gamma, h_repo=h_repo,
                                repo_level=repo_level, fold_repo=fold_repo)
    if interpret is None:
        interpret = not _on_tpu()
    qp = _pad_axis(_pad_axis(queries.astype(jnp.float32), LANE, 1, "zero"),
                   bq, 0, "zero")
    kp = _pad_axis(_pad_axis(keys.astype(jnp.float32), LANE, 1, "zero"),
                   bk, 0, "zero")
    hp = _pad_axis(h_row, bk, 1, "zero")
    # padded keys get valid == 0, payload == −1 — masked inside the kernel
    kpad = kp.shape[0] - keys.shape[0]
    mp = jnp.pad(meta.astype(jnp.int32), ((0, 0), (0, kpad)),
                 constant_values=0)
    if kpad:
        mp = mp.at[2, keys.shape[0]:].set(-1)
    cost, ca, lvl, slot, pay = fused_lookup_pallas(
        qp, kp, hp, mp, metric=metric, gamma=gamma, h_repo=h_repo,
        repo_level=repo_level, bq=bq, bk=bk, interpret=interpret,
        fold_repo=fold_repo)
    return cost[:nq], ca[:nq], lvl[:nq], slot[:nq], pay[:nq]


def mesh_axes_size(mesh, axes: tuple[str, ...]) -> int:
    """Product of the given mesh axis sizes — the lookup shard count.

    The single definition shared by the shard_map entry below,
    SimCacheNetwork.n_shards, and LookupShardPolicy.n_shards, so the
    padding contract (key axis % shard count == 0) can never drift
    between layout and dispatch.
    """
    n = 1
    for ax in axes:
        n *= mesh.shape[ax]
    return n


@functools.partial(jax.jit, static_argnames=(
    "mesh", "axes", "metric", "gamma", "h_repo", "repo_level", "bq", "bk",
    "use_pallas", "interpret"))
def sharded_fused_lookup(queries: jax.Array, keys: jax.Array,
                         h_key: jax.Array, meta: jax.Array, mesh,
                         axes: tuple[str, ...], metric: str = "l2",
                         gamma: float = 1.0, h_repo: float = 0.0,
                         repo_level: int = -1, bq: int = DEFAULT_BQ,
                         bk: int = DEFAULT_BK, use_pallas: bool = True,
                         interpret: bool | None = None
                         ) -> tuple[jax.Array, ...]:
    """Mesh-sharded fused lookup: one fused kernel launch *per shard*.

    ``keys``/``h_key``/``meta`` must already be padded so the key axis
    divides the shard count (product of the ``axes`` sizes in ``mesh``;
    padding keys carry valid == 0 — see SimCacheNetwork.sharded_layout).
    shard_map partitions the key axis into contiguous balanced chunks,
    each device scans only its resident chunk (queries replicated), and
    the per-shard minima come back stacked on a leading shard axis — the
    "tiny all-gather": 2 f32 + 3 i32 scalars per (query, shard), however
    large the catalog. ``reduce_shard_minima`` then picks the global
    winner and folds the repository, bit-identical to the single-device
    fused path.
    """
    tracecount.bump("sharded_fused_lookup")
    n_shards = mesh_axes_size(mesh, axes)
    K = keys.shape[0]
    assert K % n_shards == 0, (K, n_shards)
    spec = P(tuple(axes))

    def shard_fn(q, k, hk, m):
        cost, ca, lvl, slot, pay = fused_lookup(
            q, k, hk, m, metric=metric, gamma=gamma, h_repo=h_repo,
            repo_level=repo_level, bq=bq, bk=bk, use_pallas=use_pallas,
            interpret=interpret, fold_repo=False)
        return (cost[None], ca[None], lvl[None], slot[None], pay[None])

    parts = shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(), spec, spec, P(None, tuple(axes))),
        out_specs=(spec,) * 5,
        check_rep=False)(queries, keys, h_key, meta)
    return reduce_shard_minima(*parts, h_repo=h_repo,
                               repo_level=repo_level)


def _quantized_select(queries: jax.Array, h_key: jax.Array,
                      valid: jax.Array, kq: QuantizedRows, top_t: int,
                      tile: int, metric: str, gamma: float
                      ) -> tuple[jax.Array, jax.Array]:
    """Compressed first pass: per-query top-T candidates + a sound bound.

    Scores every key with the certified lower bound lb_C_a + h (quant.
    lb_approx_cost_block over the int8 images; invalid keys → +INF),
    tiled along the key axis so the 4×-compressed table streams through
    a cheap dense XLA matmul however large the catalog. Returns

        cand  (B, T) i32 — per-query indices of the T smallest scores
                           (−1 where the score is +INF), and
        vT    (B,)   f32 — the T-th smallest score per query.

    ``vT`` bounds every *un-selected* key's exact cost from below: a key
    cut at the tile level scores ≥ its tile's T-th smallest, whose whole
    tile-top-T (all ≤ it) reaches the merge, so ≥ T merged entries sit
    under the cut key and the merged T-th smallest vT is below it; a key
    cut at the merge level scores ≥ vT by definition; and every score is
    ≤ the exact cost by quant.py's admissibility. Hence rescoring only
    ``cand`` in exact f32 and verifying ``cost < vT`` proves the winner
    equals the full exact scan's — the same verifier contract as LSH,
    but per query. When T covers every key the bound is +INF (nothing
    is un-scanned).
    """
    nq, dim = queries.shape
    n_keys = kq.q.shape[0]
    T = min(top_t, n_keys)
    tile = max(T, min(tile, n_keys))
    qq, qs = quant.quantize_int8(queries.astype(jnp.float32))
    qd = quant.dequantize_int8(qq, qs)
    rq = quant.quant_row_radius(qs[:, 0], dim, metric)
    q_sq = jnp.sum(qd * qd, axis=-1) if metric in ("l2", "l2sq") else None

    qk = _pad_axis(kq.q, tile, 0, "zero")
    sk = _pad_axis(kq.scale, tile, 0, "zero")
    rk = _pad_axis(kq.radius, tile, 0, "zero")
    nk = _pad_axis(kq.sq_norm, tile, 0, "zero")
    hv = _pad_axis(h_key.astype(jnp.float32), tile, 0, "zero")
    vv = _pad_axis(valid, tile, 0, "zero")          # pads to False
    nt = qk.shape[0] // tile
    offs = jnp.arange(nt, dtype=jnp.int32) * tile

    def tile_scores(args):
        qt, st, rt, sqt, ht, vt, off = args
        kd = quant.dequantize_int8(qt, st)
        lb = quant.lb_approx_cost_block(qd, kd, rq, rt, metric, gamma,
                                        q_sq=q_sq, k_sq=sqt)
        score = jnp.where(vt[None, :], lb + ht[None, :], _INF)
        neg, li = jax.lax.top_k(-score, T)
        return neg, off + li.astype(jnp.int32)

    neg, gidx = jax.lax.map(tile_scores, (
        qk.reshape(nt, tile, -1), sk.reshape(nt, tile, 1),
        rk.reshape(nt, tile), nk.reshape(nt, tile),
        hv.reshape(nt, tile), vv.reshape(nt, tile), offs))
    neg = jnp.moveaxis(neg, 0, 1).reshape(nq, nt * T)
    gidx = jnp.moveaxis(gidx, 0, 1).reshape(nq, nt * T)
    neg2, sel = jax.lax.top_k(neg, T)
    cand = jnp.take_along_axis(gidx, sel, axis=1)
    cand = jnp.where(neg2 > -_INF, cand, -1)        # +INF slots: no key
    if T >= n_keys:
        return cand, jnp.full((nq,), _INF, jnp.float32)
    return cand, -neg2[:, -1]


def _quant_union_cap(n_keys: int, nq: int, top_t: int) -> int:
    """Static batch-union capacity of the rescore gather: the union of nq
    per-query top-T sets can never exceed nq·T distinct rows, so unlike
    the LSH union this one can never overflow (no dropped candidates to
    account for — vT alone is the whole bound)."""
    return max(1, min(n_keys, nq * min(top_t, n_keys)))


@functools.partial(jax.jit, static_argnames=(
    "top_t", "tile", "metric", "gamma", "h_repo", "repo_level", "bq", "bk",
    "use_pallas", "interpret", "fold_repo"))
def quantized_fused_lookup(queries: jax.Array, keys: jax.Array,
                           h_key: jax.Array, meta: jax.Array,
                           kq: QuantizedRows, top_t: int = DEFAULT_TOP_T,
                           tile: int = DEFAULT_QTILE, metric: str = "l2",
                           gamma: float = 1.0, h_repo: float = 0.0,
                           repo_level: int = -1, bq: int = DEFAULT_BQ,
                           bk: int = DEFAULT_BK, use_pallas: bool = True,
                           interpret: bool | None = None,
                           fold_repo: bool = True) -> tuple[jax.Array, ...]:
    """Compressed-first-pass variant of :func:`fused_lookup`.

    ``kq`` is the pre-quantized int8 image of ``keys`` (quant.
    quantize_rows over the *same* rows — SimCacheNetwork memoizes it
    next to the fused layout). The certified-lower-bound first pass
    selects the top ``top_t`` candidates per query, their batch union is
    compacted ascending (same helper, hence same tie-break order, as the
    LSH gather) and rescored through the exact fused kernel. Returns
    (cost, approx_cost, level, slot, payload, bound) with ``bound`` a
    **per-query** (B,) verify threshold — ``cost < bound`` proves the
    result bit-identical to the exact scan (see _quantized_select);
    unlike LSH this holds *by construction of the bound*, not merely
    with high recall, so verified rescans are rare rather than load-
    bearing.
    """
    tracecount.bump("quantized_fused_lookup")
    nq = queries.shape[0]
    if keys.shape[0] == 0:          # no cache keys at all → repository
        out = fused_lookup(queries, keys, h_key, meta, metric=metric,
                           gamma=gamma, h_repo=h_repo,
                           repo_level=repo_level, bq=bq, bk=bk,
                           use_pallas=use_pallas, interpret=interpret,
                           fold_repo=fold_repo)
        return (*out, jnp.full((nq,), _INF, jnp.float32))
    cand, bound = _quantized_select(queries, h_key, meta[3, :] > 0, kq,
                                    top_t, tile, metric, gamma)
    cap = _quant_union_cap(keys.shape[0], nq, top_t)
    kept, _ = candidate_union(cand, keys.shape[0], cap)
    gk, gh, gm = gather_candidate_rows(keys, h_key, meta, kept)
    out = fused_lookup(queries, gk, gh, gm, metric=metric, gamma=gamma,
                       h_repo=h_repo, repo_level=repo_level, bq=bq, bk=bk,
                       use_pallas=use_pallas, interpret=interpret,
                       fold_repo=fold_repo)
    return (*out, bound)


@functools.partial(jax.jit, static_argnames=(
    "mesh", "axes", "top_t", "tile", "metric", "gamma", "h_repo",
    "repo_level", "bq", "bk", "use_pallas", "interpret"))
def sharded_quantized_fused_lookup(queries: jax.Array, keys: jax.Array,
                                   h_key: jax.Array, meta: jax.Array,
                                   kq: QuantizedRows, mesh,
                                   axes: tuple[str, ...],
                                   top_t: int = DEFAULT_TOP_T,
                                   tile: int = DEFAULT_QTILE,
                                   metric: str = "l2", gamma: float = 1.0,
                                   h_repo: float = 0.0,
                                   repo_level: int = -1,
                                   bq: int = DEFAULT_BQ,
                                   bk: int = DEFAULT_BK,
                                   use_pallas: bool = True,
                                   interpret: bool | None = None
                                   ) -> tuple[jax.Array, ...]:
    """Mesh-sharded compressed lookup. ``kq`` is the flat quantized image
    of the (shard-padded) key tensor — quantization is per-row, so the
    same contiguous balanced chunking that partitions ``keys`` partitions
    it; each shard runs the first pass + exact rescore on its resident
    chunk (``fold_repo=False``) and ``reduce_shard_minima`` is untouched.
    The returned per-query bound is the min over shards of each shard's
    vT: any un-scanned key lives in some shard and costs at least that
    shard's vT ≥ the min. Padding rows (valid == 0) score +INF and are
    never selected.
    """
    tracecount.bump("sharded_quantized_fused_lookup")
    n_shards = mesh_axes_size(mesh, axes)
    K = keys.shape[0]
    assert K % n_shards == 0, (K, n_shards)
    spec = P(tuple(axes))

    def shard_fn(q, k, hk, m, kqq, kqs, kqr, kqn):
        cost, ca, lvl, slot, pay, bound = quantized_fused_lookup(
            q, k, hk, m, QuantizedRows(kqq, kqs, kqr, kqn), top_t=top_t,
            tile=tile, metric=metric, gamma=gamma, h_repo=h_repo,
            repo_level=repo_level, bq=bq, bk=bk, use_pallas=use_pallas,
            interpret=interpret, fold_repo=False)
        return (cost[None], ca[None], lvl[None], slot[None], pay[None],
                bound[None])

    parts = shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(), spec, spec, P(None, tuple(axes)),
                  spec, spec, spec, spec),
        out_specs=(spec,) * 6,
        check_rep=False)(queries, keys, h_key, meta,
                         kq.q, kq.scale, kq.radius, kq.sq_norm)
    *minima, bounds = parts
    red = reduce_shard_minima(*minima, h_repo=h_repo,
                              repo_level=repo_level)
    return (*red, jnp.min(bounds, axis=0))


@functools.partial(jax.jit, static_argnames=(
    "kind", "n_probes", "cap_union", "metric", "gamma", "h_repo",
    "repo_level", "bq", "bk", "use_pallas", "interpret", "fold_repo",
    "quantize", "top_t"))
def pruned_fused_lookup(queries: jax.Array, keys: jax.Array,
                        h_key: jax.Array, meta: jax.Array, proj: jax.Array,
                        buckets: jax.Array, kind: str = "lsh",
                        n_probes: int = 1, cap_union: int = 512,
                        metric: str = "l2", gamma: float = 1.0,
                        h_repo: float = 0.0, repo_level: int = -1,
                        bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                        use_pallas: bool = True,
                        interpret: bool | None = None,
                        fold_repo: bool = True, quantize: bool = False,
                        top_t: int = DEFAULT_TOP_T
                        ) -> tuple[jax.Array, ...]:
    """Gather-variant entry: LSH/k-means candidate pre-filter in front of
    the *existing* fused kernel (see kernels.knn.lsh).

    The query batch is hashed against ``proj``/``buckets`` (one
    CandidatePolicy's built tables over this key segment), the batch
    union of candidate rows is compacted into one ascending padded index
    tensor of static size ``cap_union``, and :func:`fused_lookup` runs
    over only the gathered (keys, h_key, meta) rows — same arithmetic,
    same masking, same tie-break order as the exact scan, on a fraction
    of the keys. Returns (cost, approx_cost, level, slot, payload,
    bound): ``bound`` is the min h over valid *un-scanned* keys (+INF if
    none), the verifier's accept threshold (``cost < bound`` proves the
    pruned result exact — lsh.py's verifier contract).

    ``quantize=True`` composes the compressed first pass *inside* the
    LSH union: the gathered rows are quantized on the fly, the top
    ``top_t`` per query survive to the exact rescore, and the returned
    bound becomes per-query (B,): min(h bound over rows outside the LSH
    union, vT over rows inside it that the first pass cut) — a key is
    either outside the union (exact cost ≥ its h ≥ the h bound) or cut
    by the first pass (exact cost ≥ its lb score ≥ vT). The exact-scan
    subunion keeps ascending global order (an ascending sub-selection of
    an ascending union), so the tie-break contract is untouched.
    """
    nq = queries.shape[0]
    if keys.shape[0] == 0:          # no cache keys at all → repository
        out = fused_lookup(queries, keys, h_key, meta, metric=metric,
                           gamma=gamma, h_repo=h_repo,
                           repo_level=repo_level, bq=bq, bk=bk,
                           use_pallas=use_pallas, interpret=interpret,
                           fold_repo=fold_repo)
        if quantize:
            return (*out, jnp.full((nq,), _INF, jnp.float32))
        return (*out, jnp.float32(_INF))
    cand = candidate_matrix(kind, proj, buckets, queries, n_probes)
    kept, kept_mask = candidate_union(cand, keys.shape[0], cap_union)
    gk, gh, gm = gather_candidate_rows(keys, h_key, meta, kept)
    bound = unscanned_h_bound(h_key, meta, kept_mask)
    if quantize:
        kq_u = quant.quantize_rows(gk, metric)
        cand2, vt = _quantized_select(queries, gh, gm[3, :] > 0, kq_u,
                                      top_t, DEFAULT_QTILE, metric, gamma)
        cap2 = _quant_union_cap(gk.shape[0], nq, top_t)
        kept2, _ = candidate_union(cand2, gk.shape[0], cap2)
        gk, gh, gm = gather_candidate_rows(gk, gh, gm, kept2)
        bound = jnp.minimum(bound, vt)
    out = fused_lookup(queries, gk, gh, gm, metric=metric, gamma=gamma,
                       h_repo=h_repo, repo_level=repo_level, bq=bq, bk=bk,
                       use_pallas=use_pallas, interpret=interpret,
                       fold_repo=fold_repo)
    return (*out, bound)


@functools.partial(jax.jit, static_argnames=(
    "mesh", "axes", "kind", "n_probes", "cap_union", "metric", "gamma",
    "h_repo", "repo_level", "bq", "bk", "use_pallas", "interpret",
    "quantize", "top_t"))
def sharded_pruned_fused_lookup(queries: jax.Array, keys: jax.Array,
                                h_key: jax.Array, meta: jax.Array,
                                proj_s: jax.Array, buckets_s: jax.Array,
                                mesh, axes: tuple[str, ...],
                                kind: str = "lsh", n_probes: int = 1,
                                cap_union: int = 512, metric: str = "l2",
                                gamma: float = 1.0, h_repo: float = 0.0,
                                repo_level: int = -1, bq: int = DEFAULT_BQ,
                                bk: int = DEFAULT_BK,
                                use_pallas: bool = True,
                                interpret: bool | None = None,
                                quantize: bool = False,
                                top_t: int = DEFAULT_TOP_T
                                ) -> tuple[jax.Array, ...]:
    """Mesh-sharded pruned lookup: per-shard tables prune each shard's
    resident chunk before its ``fold_repo=False`` fused-kernel launch.

    ``proj_s``/``buckets_s`` carry a leading (n_shards, …) axis (built
    via lsh.stack_shard_tables) that shard_map partitions together with
    the key tensor, so every shard hashes the replicated queries against
    its *own* tables and scans only its local candidate union.
    ``reduce_shard_minima`` and the tie-break order are untouched — the
    candidate mask only shrinks a shard's scan. The returned ``bound``
    is the min over shards of each shard's un-scanned-h bound, sound for
    the same verify contract as the single-device entry.
    ``quantize=True`` composes the compressed first pass inside each
    shard's LSH union (see pruned_fused_lookup) and the bound becomes
    per-query: min over shards of each shard's min(h bound, vT).
    """
    n_shards = mesh_axes_size(mesh, axes)
    K = keys.shape[0]
    assert K % n_shards == 0, (K, n_shards)
    spec = P(tuple(axes))

    def shard_fn(q, k, hk, m, pj, bks):
        cost, ca, lvl, slot, pay, bound = pruned_fused_lookup(
            q, k, hk, m, pj[0], bks[0], kind=kind, n_probes=n_probes,
            cap_union=cap_union, metric=metric, gamma=gamma, h_repo=h_repo,
            repo_level=repo_level, bq=bq, bk=bk, use_pallas=use_pallas,
            interpret=interpret, fold_repo=False, quantize=quantize,
            top_t=top_t)
        return (cost[None], ca[None], lvl[None], slot[None], pay[None],
                bound[None])

    parts = shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(), spec, spec, P(None, tuple(axes)),
                  P(tuple(axes)), P(tuple(axes))),
        out_specs=(spec,) * 6,
        check_rep=False)(queries, keys, h_key, meta, proj_s, buckets_s)
    *minima, bounds = parts
    red = reduce_shard_minima(*minima, h_repo=h_repo,
                              repo_level=repo_level)
    bound = jnp.min(bounds, axis=0) if quantize else jnp.min(bounds)
    return (*red, bound)
