"""Jitted public wrapper around the KNN Pallas kernel.

Handles padding (queries → BQ multiple with zeros, keys → BK multiple by
repeating key 0 so ties break to the genuine lower index, feature dim →
lane multiple with zeros, which preserves both L1 and L2 distances), and
falls back to the pure-jnp oracle on platforms without Pallas TPU support
unless ``interpret=True`` (the default off-TPU) is requested.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.knn.knn import (DEFAULT_BK, DEFAULT_BQ,
                                   fused_lookup_pallas, knn_pallas)
from repro.kernels.knn.ref import fused_lookup_ref, knn_ref

LANE = 128


def _pad_axis(x: jax.Array, mult: int, axis: int, mode: str) -> jax.Array:
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    if mode == "zero":
        widths = [(0, 0)] * x.ndim
        widths[axis] = (0, pad)
        return jnp.pad(x, widths)
    if mode == "repeat_first":
        first = jax.lax.slice_in_dim(x, 0, 1, axis=axis)
        reps = jnp.concatenate([first] * pad, axis=axis)
        return jnp.concatenate([x, reps], axis=axis)
    raise ValueError(mode)


def pad_for_knn(queries: jax.Array, keys: jax.Array, bq: int, bk: int
                ) -> tuple[jax.Array, jax.Array]:
    queries = _pad_axis(_pad_axis(queries, LANE, 1, "zero"), bq, 0, "zero")
    keys = _pad_axis(_pad_axis(keys, LANE, 1, "zero"), bk, 0, "repeat_first")
    return queries, keys


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("metric", "gamma", "bq", "bk",
                                              "use_pallas", "interpret"))
def nearest_approximizer(queries: jax.Array, keys: jax.Array,
                         metric: str = "l2", gamma: float = 1.0,
                         bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                         use_pallas: bool = True,
                         interpret: bool | None = None
                         ) -> tuple[jax.Array, jax.Array]:
    """min_k C_a(q, key_k) and the argmin index, per query.

    The public lookup primitive of the similarity cache: returns the
    dissimilarity cost d(q, k)^γ of the best stored approximizer and its
    slot index.
    """
    nq = queries.shape[0]
    if not use_pallas:
        return knn_ref(queries, keys, metric, gamma)
    if interpret is None:
        interpret = not _on_tpu()
    qp, kp = pad_for_knn(queries.astype(jnp.float32),
                         keys.astype(jnp.float32), bq, bk)
    mind, argm = knn_pallas(qp, kp, metric=metric, gamma=gamma, bq=bq, bk=bk,
                            interpret=interpret)
    return mind[:nq], argm[:nq]


@functools.partial(jax.jit, static_argnames=(
    "metric", "gamma", "h_repo", "repo_level", "bq", "bk", "use_pallas",
    "interpret"))
def fused_lookup(queries: jax.Array, keys: jax.Array, h_key: jax.Array,
                 meta: jax.Array, metric: str = "l2", gamma: float = 1.0,
                 h_repo: float = 0.0, repo_level: int = -1,
                 bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                 use_pallas: bool = True, interpret: bool | None = None
                 ) -> tuple[jax.Array, ...]:
    """Network-wide nearest-approximizer query, fused.

    ``keys`` (K, d) is the concatenation of every cache level's stored
    embeddings; ``h_key`` (K,) the per-key retrieval cost h(level(k));
    ``meta`` (4, K) i32 rows (level, slot, payload, valid). A single
    blocked scan returns, per query, argmin over all keys *and* the
    repository (a virtual key with C_a = 0, h = h_repo) of
    C_a(q, k)^γ + h — eq. (1) as one kernel launch. Returns
    (cost, approx_cost, level, slot, payload), each (B,).
    """
    nq = queries.shape[0]
    if keys.shape[0] == 0:          # no cache keys at all → repository
        return (jnp.full((nq,), h_repo, jnp.float32),
                jnp.zeros((nq,), jnp.float32),
                jnp.full((nq,), repo_level, jnp.int32),
                jnp.zeros((nq,), jnp.int32),
                jnp.full((nq,), -1, jnp.int32))
    h_row = h_key.reshape(1, -1).astype(jnp.float32)
    if not use_pallas:
        return fused_lookup_ref(queries, keys, h_row[0], meta, metric=metric,
                                gamma=gamma, h_repo=h_repo,
                                repo_level=repo_level)
    if interpret is None:
        interpret = not _on_tpu()
    qp = _pad_axis(_pad_axis(queries.astype(jnp.float32), LANE, 1, "zero"),
                   bq, 0, "zero")
    kp = _pad_axis(_pad_axis(keys.astype(jnp.float32), LANE, 1, "zero"),
                   bk, 0, "zero")
    hp = _pad_axis(h_row, bk, 1, "zero")
    # padded keys get valid == 0, payload == −1 — masked inside the kernel
    kpad = kp.shape[0] - keys.shape[0]
    mp = jnp.pad(meta.astype(jnp.int32), ((0, 0), (0, kpad)),
                 constant_values=0)
    if kpad:
        mp = mp.at[2, keys.shape[0]:].set(-1)
    cost, ca, lvl, slot, pay = fused_lookup_pallas(
        qp, kp, hp, mp, metric=metric, gamma=gamma, h_repo=h_repo,
        repo_level=repo_level, bq=bq, bk=bk, interpret=interpret)
    return cost[:nq], ca[:nq], lvl[:nq], slot[:nq], pay[:nq]
