from repro.kernels.knn.ops import nearest_approximizer, pad_for_knn
from repro.kernels.knn.ref import knn_ref

__all__ = ["nearest_approximizer", "pad_for_knn", "knn_ref"]
