from repro.kernels.knn.ops import (fused_lookup, mesh_axes_size,
                                   nearest_approximizer, pad_for_knn,
                                   sharded_fused_lookup)
from repro.kernels.knn.ref import (fused_lookup_ref, knn_ref,
                                   pad_to_shards, reduce_shard_minima,
                                   sharded_fused_lookup_ref)

__all__ = ["nearest_approximizer", "pad_for_knn", "knn_ref",
           "fused_lookup", "fused_lookup_ref", "sharded_fused_lookup",
           "sharded_fused_lookup_ref", "reduce_shard_minima",
           "pad_to_shards", "mesh_axes_size"]
