from repro.kernels.knn.ops import (fused_lookup, nearest_approximizer,
                                   pad_for_knn)
from repro.kernels.knn.ref import fused_lookup_ref, knn_ref

__all__ = ["nearest_approximizer", "pad_for_knn", "knn_ref",
           "fused_lookup", "fused_lookup_ref"]
