from repro.kernels.knn.gains import (duel_virtual_costs, placement_gains,
                                     placement_gains_matrix,
                                     sharded_placement_gains)
from repro.kernels.knn.lsh import (CandidatePolicy, CandidateTables,
                                   KMeansPolicy, SimHashPolicy,
                                   default_policy, stack_shard_tables)
from repro.kernels.knn.ops import (fused_lookup, mesh_axes_size,
                                   nearest_approximizer, pad_for_knn,
                                   pruned_fused_lookup,
                                   sharded_fused_lookup,
                                   sharded_pruned_fused_lookup)
from repro.kernels.knn.ref import (fused_lookup_ref, knn_ref,
                                   pad_to_shards, placement_gains_ref,
                                   pruned_fused_lookup_ref,
                                   reduce_shard_minima,
                                   sharded_fused_lookup_ref,
                                   sharded_pruned_fused_lookup_ref)

__all__ = ["nearest_approximizer", "pad_for_knn", "knn_ref",
           "fused_lookup", "fused_lookup_ref", "sharded_fused_lookup",
           "sharded_fused_lookup_ref", "reduce_shard_minima",
           "pad_to_shards", "mesh_axes_size", "CandidatePolicy",
           "CandidateTables", "SimHashPolicy", "KMeansPolicy",
           "default_policy", "stack_shard_tables", "pruned_fused_lookup",
           "pruned_fused_lookup_ref", "sharded_pruned_fused_lookup",
           "sharded_pruned_fused_lookup_ref", "duel_virtual_costs",
           "placement_gains",
           "placement_gains_matrix", "sharded_placement_gains",
           "placement_gains_ref"]
