"""Shared int8 row quantization + admissible lower-bound distance blocks.

One quantizer for the whole repo: the cross-pod gradient exchange
(ft/compress.py) and the compressed first-pass distance path of the
lookup/gain kernels (kernels/knn/{ops,gains}.py) both quantize per-row
symmetric int8 through :func:`quantize_int8` here.

The first-pass machinery computes *certified lower bounds* on the exact
distance between the original f32 rows, from their int8 images alone:

    d(q, k)  ≥  d(q~, k~) − r_q − r_k                 (triangle inequality)

where q~ = dequantize(quantize(q)) and r_q ≥ ‖q − q~‖ is a per-row
radius derived from the quantization scale. Every computational step on
top of the mathematical inequality is made *directionally safe* against
f32 rounding with explicit slack factors (standard per-op error bounds,
inflated 4×), so the chain

    exact C_a(q, k) = d(q, k)^γ ≥ lb_approx_cost(q~, k~)

holds for every pair — which is what makes ``lookup(..., quantize=True,
verify=True)`` exact *by construction*: a pruned winner whose cost beats
the lower bound of every un-scanned key provably equals the full-scan
winner, and the remaining queries are re-scanned through the exact
kernel (the same admissible-bound machinery LSH ``verify=True`` uses).

Error budget per element (symmetric scale s = amax / 127):

* rounding of x/s to the int8 grid:            ≤ s/2
* f32 rounding of the division itself:          ≤ 127·eps·s
* f32 rounding of the dequantized product s·q:  ≤ 127·eps·s

→ |x − x~| ≤ s·(0.5 + 254·eps) < s·ELEM_ERR with ELEM_ERR = 0.5005.
Row radii follow by norm equivalence: r = ELEM_ERR·s·√D (l2 family),
r = ELEM_ERR·s·D (l1).

Zero-row guard: a row of exact zeros gets scale **0.0** (and quantizes
to exact zeros, dequantizes to exact zeros, radius 0 — the bound is
tight), instead of the historic ``1e-20`` floor that routed zero rows
through a denormal scale. Sub-denormal rows (amax < 127·F32_TINY) clamp
the scale to the smallest *normal* f32 so the division never produces
inf/NaN; the ≤ s/2 rounding bound still holds because the clamped scale
only grows.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

F32_TINY = 1.1754944e-38      # smallest normal f32
F32_EPS = 1.1920929e-07       # f32 machine epsilon
ELEM_ERR = 0.5005             # per-element |x − x~| ≤ ELEM_ERR·scale
_SQRT_DEFLATE = 1.0 - 4.0 * F32_EPS
_POW_DEFLATE = 1.0 - 8.0 * F32_EPS


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-row (trailing dim) symmetric int8 quantization.

    Returns (q int8, scale f32 with keepdims). All-zero rows get scale
    exactly 0.0 (see module docstring); callers can rely on
    ``dequantize_int8(q, 0.0) == 0`` bit-exactly.
    """
    xf = x.astype(jnp.float32)
    if x.ndim == 0:
        xf = xf[None]
    amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    scale = jnp.where(amax > 0.0,
                      jnp.maximum(amax / 127.0, F32_TINY), 0.0)
    safe = jnp.where(scale > 0.0, scale, 1.0)
    q = jnp.clip(jnp.round(xf / safe), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def quant_row_radius(scale: jax.Array, dim: int, metric: str) -> jax.Array:
    """Per-row radius r ≥ d_metric(x, x~) from the quantization scale.

    ``scale`` is the per-row scale with the trailing keepdim squeezed or
    not (broadcasts either way); ``dim`` the *unpadded* feature count
    (zero-padding adds exactly-zero elements with zero error). For the
    l2 family the radius is in *distance* units (callers of the l2sq
    metric still subtract it from the un-squared distance).
    """
    if metric in ("l2", "l2sq"):
        return scale * (ELEM_ERR * float(dim) ** 0.5)
    if metric == "l1":
        return scale * (ELEM_ERR * float(dim))
    raise ValueError(f"unknown metric {metric!r}")


class QuantizedRows(NamedTuple):
    """int8 image of a row tensor + everything the lb blocks consume.

    ``deq`` is *not* stored (4× memory win is the point); consumers
    rematerialize tiles with ``dequantize_int8`` — bit-deterministic,
    so the precomputed ``sq_norm`` (Σ deq² per row) stays consistent
    with any tile-local recompute.
    """
    q: jax.Array          # (N, D) int8
    scale: jax.Array      # (N, 1) f32, 0.0 for all-zero rows
    radius: jax.Array     # (N,)  f32, metric-space error radius
    sq_norm: jax.Array    # (N,)  f32, Σ dequantized² (l2 family; 0 for l1)


def quantize_rows(x: jax.Array, metric: str,
                  dim: int | None = None) -> QuantizedRows:
    """Quantize a row tensor and precompute the lb-block side tables.

    ``dim`` overrides the radius dimension when the trailing axis
    carries zero padding (padded elements quantize exactly → error 0)."""
    q, scale = quantize_int8(x)
    radius = quant_row_radius(scale[:, 0], x.shape[-1] if dim is None
                              else dim, metric)
    if metric in ("l2", "l2sq"):
        deq = dequantize_int8(q, scale)
        sq_norm = jnp.sum(deq * deq, axis=-1)
    else:
        sq_norm = jnp.zeros(x.shape[:-1], jnp.float32)
    return QuantizedRows(q=q, scale=scale, radius=radius, sq_norm=sq_norm)


def _dot_slack(dim: int) -> float:
    """Directed f32 slack factor for the |q|²+|k|²−2q·k contraction:
    absolute error ≤ _dot_slack(D)·(|q|² + |k|²) — the D-term dot
    product's Σ|q_i·k_i| ≤ (|q|²+|k|²)/2 bound times D·eps, with the
    few extra adds/subs and a 4× safety factor folded in."""
    return 4.0 * (dim + 4.0) * F32_EPS


def lb_distance_block(qd: jax.Array, kd: jax.Array,
                      rq: jax.Array, rk: jax.Array, metric: str,
                      q_sq: jax.Array | None = None,
                      k_sq: jax.Array | None = None) -> jax.Array:
    """(B, K) certified lower bound on d_metric(orig_q, orig_k).

    ``qd``/``kd`` are the *dequantized* f32 rows, ``rq``/``rk`` the
    per-row radii from :func:`quant_row_radius`. This is the quantized
    variant of the fused kernel's ``_distance_block`` (same MXU-identity
    l2 form / broadcast l1 form), minus radii, minus directed f32
    slack — admissible for every pair by the module-docstring budget.
    For ``l2sq`` the returned bound is on the *squared* distance,
    mirroring ``pairwise_distance``'s metric convention.
    """
    dim = qd.shape[-1]
    rpair = rq[:, None] + rk[None, :]
    if metric in ("l2", "l2sq"):
        q_sq = jnp.sum(qd * qd, axis=-1) if q_sq is None else q_sq
        k_sq = jnp.sum(kd * kd, axis=-1) if k_sq is None else k_sq
        d2 = q_sq[:, None] + k_sq[None, :] - 2.0 * (qd @ kd.T)
        slack = _dot_slack(dim) * (q_sq[:, None] + k_sq[None, :])
        d = jnp.sqrt(jnp.maximum(d2 - slack, 0.0)) * _SQRT_DEFLATE
        lb = jnp.maximum(d - rpair, 0.0)
        if metric == "l2sq":
            # fl(lb·lb) ≤ lb²·(1+eps) → one more deflate keeps it under
            return (lb * lb) * _SQRT_DEFLATE
        return lb
    if metric == "l1":
        d1 = jnp.sum(jnp.abs(qd[:, None, :] - kd[None, :, :]), axis=-1)
        # non-negative summands → summation error is relative: ≤ D·eps·d1
        d1 = d1 * (1.0 - 4.0 * dim * F32_EPS)
        return jnp.maximum(d1 - rpair, 0.0)
    raise ValueError(f"unknown metric {metric!r}")


def lb_approx_cost_block(qd: jax.Array, kd: jax.Array,
                         rq: jax.Array, rk: jax.Array, metric: str,
                         gamma: float,
                         q_sq: jax.Array | None = None,
                         k_sq: jax.Array | None = None) -> jax.Array:
    """(B, K) certified lower bound on C_a = d(orig_q, orig_k)^γ.

    γ ≥ 0 and lb ≥ 0 make x ↦ x^γ monotone, so the power of the
    distance bound is a cost bound; one deflate absorbs ``jnp.power``'s
    f32 rounding (pow is within a couple of ulp on every backend here).
    """
    lb = lb_distance_block(qd, kd, rq, rk, metric, q_sq=q_sq, k_sq=k_sq)
    if gamma == 1.0:
        return lb
    return jnp.power(lb, gamma) * _POW_DEFLATE


def lb_approx_cost_tiles(queries: jax.Array, kq: QuantizedRows,
                         metric: str, gamma: float, dim: int | None = None
                         ) -> jax.Array:
    """(B, K) lower-bound C_a of a query batch against pre-quantized
    keys, quantizing the queries on the fly. ``dim`` overrides the
    radius dimension when the trailing axis carries zero padding
    (zero elements quantize exactly; their error is 0)."""
    dim = queries.shape[-1] if dim is None else dim
    qq, qs = quantize_int8(queries)
    qd = dequantize_int8(qq, qs)
    rq = quant_row_radius(qs[:, 0], dim, metric)
    kd = dequantize_int8(kq.q, kq.scale)
    return lb_approx_cost_block(qd, kd, rq, kq.radius, metric, gamma,
                                k_sq=kq.sq_norm)
