"""Pure-jnp oracle for the flash-attention kernel: standard (unfused)
GQA attention, numerically identical semantics (f32 softmax)."""
from __future__ import annotations

from repro.models.layers import gqa_attention


def flash_ref(q, k, v, causal: bool = True, kv_len=None):
    """q: (B, Sq, H, Dh); k, v: (B, Skv, KH, Dh) → (B, Sq, H, Dh)."""
    return gqa_attention(q, k, v, causal=causal, kv_len=kv_len)
