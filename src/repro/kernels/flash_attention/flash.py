"""Pallas TPU kernel: flash-attention forward (fused online-softmax).

The memory-term lever identified in EXPERIMENTS.md §Perf: the unfused
baseline writes the (B, H, Sq, Skv) score/probability matrices to HBM
several times per pass; this kernel keeps each (BQ, BK) score tile in
VMEM and maintains the online-softmax running (max m, normalizer l,
accumulator o) per query row, so HBM traffic drops to q/k/v/o — the
attention memory floor.

Layout / tiling:
  * inputs flattened to (B·H, S, Dh); grid = (B·H, Sq/BQ, Skv/BK) with
    the KV axis minor (sequential) — the (m, l, o) running state lives
    in the output VMEM blocks, indexed invariantly in the KV step (the
    same accumulation idiom as kernels/knn and kernels/gain);
  * GQA without materializing repeated KV: the K/V BlockSpec index maps
    flat head bh → kv head via bh // group (integer index arithmetic in
    the spec, zero data movement);
  * causal + length masking from global tile offsets; the final KV step
    normalizes o by l.
  * BQ = BK = 128 keeps the working set (q, k, v, s tiles + state)
    ≈ 0.6 MB ≪ VMEM, with 128-aligned MXU matmuls.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BQ = 128
DEFAULT_BK = 128
_NEG = -1.0e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, *,
                  bq: int, bk: int, scale: float, causal: bool,
                  kv_len: int, n_kv_blocks: int):
    qt = pl.program_id(1)
    kt = pl.program_id(2)
    q = q_ref[0].astype(jnp.float32)                      # (BQ, Dh)
    k = k_ref[0].astype(jnp.float32)                      # (BK, Dh)
    v = v_ref[0].astype(jnp.float32)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    q_idx = qt * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_idx = kt * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = k_idx < kv_len
    if causal:
        mask = mask & (k_idx <= q_idx)
    s = jnp.where(mask, s, _NEG)

    @pl.when(kt == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        o_ref[...] = jnp.zeros_like(o_ref)

    m_prev = m_ref[0]                                     # (BQ, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)                                # (BQ, BK)
    corr = jnp.exp(m_prev - m_new)                        # (BQ, 1)
    l_new = l_ref[0] * corr + jnp.sum(p, axis=1, keepdims=True)
    o_new = o_ref[0] * corr + jnp.dot(p, v,
                                      preferred_element_type=jnp.float32)
    m_ref[0] = m_new
    l_ref[0] = l_new
    o_ref[0] = o_new

    @pl.when(kt == n_kv_blocks - 1)
    def _finalize():
        o_ref[0] = o_ref[0] / jnp.maximum(l_ref[0], 1e-30)


@functools.partial(jax.jit, static_argnames=(
    "n_groups", "scale", "causal", "kv_len", "bq", "bk", "interpret"))
def flash_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                 n_groups: int, scale: float, causal: bool, kv_len: int,
                 bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                 interpret: bool = True):
    """q: (BH, Sq, Dh); k, v: (BKVH, Skv, Dh) with BH = BKVH·n_groups·B
    ordering (bh → kv row bh // n_groups). Pre-padded: Sq % bq == 0,
    Skv % bk == 0. Returns o (BH, Sq, Dh) f32."""
    BH, Sq, Dh = q.shape
    Skv = k.shape[1]
    grid = (BH, Sq // bq, Skv // bk)
    kernel = functools.partial(
        _flash_kernel, bq=bq, bk=bk, scale=scale, causal=causal,
        kv_len=kv_len, n_kv_blocks=Skv // bk)
    o, m, l = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, Dh), lambda bh, qt, kt: (bh, qt, 0)),
            pl.BlockSpec((1, bk, Dh),
                         lambda bh, qt, kt, g=n_groups: (bh // g, kt, 0)),
            pl.BlockSpec((1, bk, Dh),
                         lambda bh, qt, kt, g=n_groups: (bh // g, kt, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, Dh), lambda bh, qt, kt: (bh, qt, 0)),
            pl.BlockSpec((1, bq, 1), lambda bh, qt, kt: (bh, qt, 0)),
            pl.BlockSpec((1, bq, 1), lambda bh, qt, kt: (bh, qt, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, Sq, Dh), jnp.float32),
            jax.ShapeDtypeStruct((BH, Sq, 1), jnp.float32),
            jax.ShapeDtypeStruct((BH, Sq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    del m, l
    return o
