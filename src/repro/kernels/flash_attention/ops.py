"""Jitted public wrapper for the flash-attention forward kernel.

Handles the (B, S, H, Dh) ↔ (B·H, S, Dh) layout, GQA head grouping (the
kernel indexes KV heads via block maps — no repeat), and seq padding
(padded KV masked inside the kernel via kv_len; padded queries sliced
off). Falls back to the jnp oracle when ``use_pallas=False``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash import (DEFAULT_BK, DEFAULT_BQ,
                                                 flash_pallas)
from repro.kernels.flash_attention.ref import flash_ref
from repro.kernels.knn.ops import _on_tpu


def _pad_seq(x: jax.Array, mult: int) -> jax.Array:
    pad = (-x.shape[1]) % mult
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    return x


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk",
                                              "use_pallas", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, bq: int = DEFAULT_BQ,
                    bk: int = DEFAULT_BK, use_pallas: bool = True,
                    interpret: bool | None = None) -> jax.Array:
    """Fused GQA attention forward. q: (B, Sq, H, Dh); k, v:
    (B, Skv, KH, Dh), H % KH == 0. Returns (B, Sq, H, Dh) in q.dtype."""
    if not use_pallas:
        return flash_ref(q, k, v, causal=causal)
    if interpret is None:
        interpret = not _on_tpu()
    B, Sq, H, Dh = q.shape
    Skv, KH = k.shape[1], k.shape[2]
    groups = H // KH
    scale = 1.0 / float(Dh) ** 0.5

    qf = _pad_seq(q.transpose(0, 2, 1, 3).reshape(B * H, Sq, Dh), bq)
    kf = _pad_seq(k.transpose(0, 2, 1, 3).reshape(B * KH, Skv, Dh), bk)
    vf = _pad_seq(v.transpose(0, 2, 1, 3).reshape(B * KH, Skv, Dh), bk)
    o = flash_pallas(qf, kf, vf, n_groups=groups, scale=scale,
                     causal=causal, kv_len=Skv, bq=bq, bk=bk,
                     interpret=interpret)
    o = o[:, :Sq].reshape(B, H, Sq, Dh).transpose(0, 2, 1, 3)
    return o.astype(q.dtype)
